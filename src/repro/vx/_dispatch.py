"""vx verbs — argument normalization over the spec→plan→program pipeline.

Since PR 4 this module contains NO executor closures: every verb (and
both ``_many`` forms) normalizes its operands, resolves the policy, and
then lowers through the ONE pipeline in ``repro.vx.lower``:

    spec  -> lower.lower(op, specs, impl, shard)   # a Program (vx/program.py)
          -> lower.executor(program, specs, shard) # compiled, PLANS-cached
          -> executor(*operands)

Programs are keyed by spec (dtype + vl included), resolved impl, and the
SHARD LAYOUT: passing ``shard=vx.Shard(axes, axis, mesh)`` lowers the
access shard-locally under ``shard_map`` (offset-rebased per-shard plans
for strided patterns, local lane permutation for segment transposition)
instead of slicing a sharded leaf globally.  ``core/accessfuse.py``'s
StepScheduler rides the same pipeline — its merge is the program-level
``vx.program.fuse`` pass.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.vx import lower as _lower
from repro.vx import program as _program
from repro.vx.policy import Policy, resolve
from repro.vx.spec import (BANK, AccessSpec, Compact, Indexed, Paged,
                           Segment, Strided)

Shard = _program.Shard


def _is_static(stride) -> bool:
    return isinstance(stride, (int, np.integer))


def _fold_routing(spec: Indexed, shift, valid) -> Indexed | None:
    """Promote a host-known (shift, valid) routing into the spec so the
    access compiles through the plan stage (constant take-masks, memoized
    under the spec key).  Traced operands return None (dynamic network)."""
    if spec.routing is not None:
        if shift is not None or valid is not None:
            raise ValueError(
                f"{spec} already folds a static routing; do not also pass "
                f"shift=/valid=")
        return spec
    host = (np.ndarray, list, tuple)
    if isinstance(shift, host) and isinstance(valid, host):
        return dataclasses.replace(
            spec, routing=(tuple(np.asarray(shift, np.int64).tolist()),
                           tuple(np.asarray(valid, bool).tolist())))
    return None


# ---------------------------------------------------------------------------
# gather / scatter (Strided, Indexed)
# ---------------------------------------------------------------------------

def _static_strided(spec: Strided, stride) -> Strided | None:
    """The spec with a compile-time stride folded in, or None if the
    stride is runtime (traced)."""
    if not spec.runtime:
        if stride is not None:
            raise ValueError(
                f"stride= was passed but {spec} already pins stride="
                f"{spec.stride}; use stride=vx.BANK in the spec for "
                f"call-time strides")
        return spec
    if stride is None:
        raise ValueError(
            "spec has stride=vx.BANK: pass the runtime stride as stride=")
    if _is_static(stride):
        return dataclasses.replace(spec, stride=int(stride))
    return None


def _bind_scales(spec: Paged, scales):
    """Fold the runtime ``scales`` operand into a quantized spec: its
    dtype becomes ``spec.scale_dtype``, so the quantized program is a
    DISTINCT plan-cache entry from the float one (spec fields are the
    cache key).  Validates presence both ways."""
    if scales is None:
        if spec.quantized:
            raise ValueError(f"{spec} is quantized: pass scales=")
        return spec
    if spec.scale_dtype is None:
        return dataclasses.replace(spec, scale_dtype=str(scales.dtype))
    return spec


def gather(spec: AccessSpec, buf: jax.Array, *, stride=None, shift=None,
           valid=None, table=None, scales=None,
           policy: Policy | str | None = None,
           shard: Shard | None = None) -> jax.Array:
    """Dense read through the access described by ``spec``.

    * :class:`Strided` — ``(..., n) -> (..., vl)``; a ``stride=vx.BANK``
      spec takes the runtime stride via ``stride=`` and dispatches through
      the plan bank's ``lax.switch`` (compiled masks for banked strides,
      dynamic-count network otherwise; either sign engages the Reverser).
    * :class:`Indexed` — DROM gather with per-lane ``shift`` and ``valid``
      operands.  Host-known routings (numpy/list/tuple) are folded into
      the spec and compile through the plan stage (constant take-masks);
      traced operands take the dynamic-count network.
    * :class:`Paged` — page-table gather over a ``(*lead, P, ps, *trail)``
      pool: ``table=`` is the runtime ``(*batch, pages)`` int32 page
      table (entries ``< 0`` read as zeros); returns the gathered
      ``(*lead, *batch, pages*ps, *trail)`` sequences.  ``shard=`` (on
      the pool's page axis, ``Shard.axis == -(trail+2)``) gathers
      shard-locally from the owned page block and psum-merges — the
      sharded pool is never sliced globally.  A QUANTIZED pool passes
      its per-page scale tensor as ``scales=`` and returns dequantized
      float sequences from the same one-program gather.

    For the other specs ``shard=`` marks ``buf``'s lane axis as sharded:
    the access lowers to shard-local offset-rebased plans under
    ``shard_map`` (replicated output), never a global slice of the
    sharded leaf.
    """
    pol = resolve(policy)
    if isinstance(spec, Strided):
        spec = spec.bind(buf.dtype)
        static = _static_strided(spec, stride)
        if static is not None:
            return _lower.run("gather.plan", static, pol.impl, buf,
                              shard=shard)
        return _lower.run("bank.gather", spec, pol.impl, buf, stride,
                          shard=shard)
    if isinstance(spec, Paged):
        if table is None:
            raise ValueError("Paged gather needs the page table as table=")
        spec = _bind_scales(spec.bind(buf.dtype), scales)
        if spec.quantized:
            return _lower.run("paged.gather", spec, pol.impl,
                              buf, scales, table, shard=shard)
        return _lower.run("paged.gather", spec, pol.impl,
                          buf, table, shard=shard)
    if isinstance(spec, Indexed):
        spec = spec.bind(buf.dtype)
        static = _fold_routing(spec, shift, valid)
        if static is not None:
            return _lower.run("idx.gather", static, pol.impl, buf,
                              shard=shard)
        if shift is None or valid is None:
            raise ValueError("Indexed gather needs shift= and valid= "
                             "(or a spec with routing=)")
        return _lower.run("idx.gather", spec, pol.impl,
                          buf, shift, valid, shard=shard)
    raise TypeError(f"gather does not accept {type(spec).__name__} specs")


def scatter(spec: AccessSpec, buf: jax.Array, values: jax.Array, *,
            stride=None, shift=None, valid=None, table=None, pos=None,
            scales=None, policy: Policy | str | None = None,
            shard: Shard | None = None):
    """Write/merge through the access described by ``spec``.

    * :class:`Strided` — merge dense ``values`` into strided positions of
      ``buf`` (read-modify-write; returns the updated window).  With
      ``shard=`` the window stays sharded: each shard merges only the
      value lanes it owns (rebased plan), no collective.
    * :class:`Paged` — the decode append: write one ``(*batch, *trail)``
      beat per table row into pool ``buf`` at per-row position ``pos=``
      through the page table ``table=`` (rows with ``pos < 0`` or an
      unallocated page entry are dropped); returns the updated pool.
      A QUANTIZED pool passes ``scales=`` and gets ``(pool, scales)``
      back — the beat quantizes on write and the page scale widens
      monotonically (see vx/lower.py).
    * :class:`Indexed` — raw DROM scatter of ``values`` (``buf`` is unused;
      pass None); returns ``(payload, occupancy)``.
    * :class:`Compact` — expansion (the compaction inverse): ``buf`` is the
      boolean mask, ``values`` the packed rows; returns rows scattered back
      to the mask positions, zeros elsewhere.
    """
    pol = resolve(policy)
    if isinstance(spec, Paged):
        if table is None or pos is None:
            raise ValueError("Paged scatter needs table= and pos=")
        spec = _bind_scales(spec.bind(buf.dtype), scales)
        if spec.quantized:
            return _lower.run("paged.scatter", spec, pol.impl,
                              buf, scales, values, table, pos, shard=shard)
        return _lower.run("paged.scatter", spec, pol.impl,
                          buf, values, table, pos, shard=shard)
    if isinstance(spec, Strided):
        spec = spec.bind(buf.dtype)
        static = _static_strided(spec, stride)
        if static is not None:
            return _lower.run("scatter.plan", static, pol.impl, buf, values,
                              shard=shard)
        return _lower.run("bank.scatter", spec, pol.impl, buf, values,
                          stride, shard=shard)
    if isinstance(spec, Indexed):
        if shift is None or valid is None:
            raise ValueError("Indexed scatter needs shift= and valid=")
        return _lower.run("idx.scatter", spec.bind(values.dtype), pol.impl,
                          values, shift, valid, shard=shard)
    if isinstance(spec, Compact):
        return _lower.run("compact.expand", spec.bind(values.dtype),
                          pol.impl, values, buf, shard=shard)
    raise TypeError(f"scatter does not accept {type(spec).__name__} specs")


# ---------------------------------------------------------------------------
# transpose (Segment): AoS <-> SoA
# ---------------------------------------------------------------------------

def transpose(spec: Segment, x, *, policy: Policy | str | None = None,
              shard: Shard | None = None):
    """Segment transposition, direction inferred from the operand:

    * a single AoS array ``(..., n)`` -> list of ``fields`` SoA arrays
      ``(..., n/fields)`` (segment load / deinterleave),
    * a sequence of ``fields`` SoA arrays -> one AoS array (segment store /
      interleave).

    ``shard=`` (an OUTER axis, ``Shard.axis <= -2``) executes the lane
    permutation shard-locally under ``shard_map`` — the sharded operand is
    never gathered.
    """
    if not isinstance(spec, Segment):
        raise TypeError(f"transpose needs a Segment spec, got "
                        f"{type(spec).__name__}")
    pol = resolve(policy)
    if isinstance(x, (list, tuple)):
        parts = list(x)
        if len(parts) != spec.fields:
            raise ValueError(f"expected {spec.fields} fields, "
                             f"got {len(parts)}")
        return _lower.run("seg.int", spec.bind(parts[0].dtype), pol.impl,
                          parts, shard=shard)
    if x.shape[-1] != spec.n:
        raise ValueError(f"AoS beat has {x.shape[-1]} lanes, spec.n is "
                         f"{spec.n}")
    return _lower.run("seg.deint", spec.bind(x.dtype), pol.impl, x,
                      shard=shard)


# ---------------------------------------------------------------------------
# compact (Compact): masked compaction / packed indices
# ---------------------------------------------------------------------------

def compact(spec: Compact, mask: jax.Array, rows: jax.Array | None = None,
            *, policy: Policy | str | None = None):
    """Order-preserving masked compaction.

    With ``rows`` — pack the masked rows to the front; returns
    ``(packed_rows, packed_valid)``, truncated to ``spec.capacity`` rows
    when ``cap`` is set.  Without ``rows`` — return the packed *indices*
    of set mask bits (first ``spec.capacity`` kept), the MoE dispatch
    primitive (runtime-count plan-bank member; no conflict reductions)."""
    if not isinstance(spec, Compact):
        raise TypeError(f"compact needs a Compact spec, got "
                        f"{type(spec).__name__}")
    pol = resolve(policy)  # validate even on the impl-independent path
    if rows is None:
        return _lower.run("compact.ids", spec, pol.impl, mask)
    return _lower.run("compact.rows", spec.bind(rows.dtype), pol.impl,
                      rows, mask)


# ---------------------------------------------------------------------------
# batched forms: one launch for a whole step's same-shape accesses
# ---------------------------------------------------------------------------

def gather_many(specs, bufs, *, table=None, scales=None,
                policy: Policy | str | None = None,
                shard: Shard | None = None):
    """Whole-step batched gather — ONE kernel launch, one mask operand.

    * ``specs`` a sequence of :class:`Strided` sharing (n, vl) with
      per-access (stride, offset), ``bufs`` the matching windows (a
      sequence, or an already-stacked ``(A, ..., n)`` array): the fused
      concatenated-mask transaction.  Returns the stacked ``(A, ..., vl)``.
    * ``specs`` a single :class:`Segment`, ``bufs`` a sequence of
      same-shape AoS arrays: the step-fused segment load (``shard=``
      supported: the stacked group transposes shard-locally).  Returns one
      field list per input array.
    * ``specs`` a single :class:`Paged`, ``bufs`` a sequence of same-shape
      pools sharing one runtime ``table=``: the whole-step paged read —
      all pools stack and the heterogeneous per-request lengths (encoded
      in the table rows) fuse into ONE page-granular gather program
      (``shard=`` supported on the page axis).  Quantized pools pass
      their per-page scale tensors as ``scales=`` (stacked the same
      way); the dequant rides the SAME single program.  Returns one
      gathered array per pool.
    """
    pol = resolve(policy)
    if isinstance(specs, Paged):
        if table is None:
            raise ValueError("Paged gather_many needs table=")
        pools = list(bufs)
        scl = None if scales is None else list(scales)
        spec = _bind_scales(specs.bind(pools[0].dtype),
                            None if scl is None else scl[0])
        prog = _program.fuse([_lower.lower("paged.gather", spec, pol.impl,
                                           shard)] * len(pools))
        stacked = pools[0] if len(pools) == 1 else jnp.stack(pools)
        exe = _lower.executor(prog, (spec,) * len(pools), shard)
        if scl is not None:
            sstk = scl[0] if len(scl) == 1 else jnp.stack(scl)
            out = exe(stacked, sstk, table)
        else:
            out = exe(stacked, table)
        return [out] if len(pools) == 1 else [out[a]
                                              for a in range(len(pools))]
    if isinstance(specs, Segment):
        aos_list = list(bufs)
        spec = specs.bind(aos_list[0].dtype)
        prog = _program.fuse([_lower.lower("seg.deint", spec, pol.impl,
                                           shard)] * len(aos_list))
        stacked = (aos_list[0] if len(aos_list) == 1
                   else jnp.stack(aos_list))
        outs = _lower.executor(prog, (spec,) * len(aos_list), shard)(stacked)
        if len(aos_list) == 1:
            return [list(outs)]
        return [[o[a] for o in outs] for a in range(len(aos_list))]
    specs = list(specs)
    if not specs or not all(isinstance(s, Strided) for s in specs):
        raise TypeError("gather_many needs Strided specs or one Segment")
    if len({s.vl for s in specs}) != 1 or len({s.n for s in specs}) != 1:
        raise ValueError("fused gather needs one shared (n, vl)")
    windows = bufs if isinstance(bufs, jax.Array) else jnp.stack(list(bufs))
    specs = tuple(s.bind(windows.dtype) for s in specs)
    prog = _program.fuse([_lower.lower("gather.plan", s, pol.impl, shard)
                          for s in specs])
    return _lower.executor(prog, specs, shard)(windows)


def scatter_many(spec: Segment, groups: Sequence[Sequence[jax.Array]], *,
                 policy: Policy | str | None = None,
                 shard: Shard | None = None) -> list[jax.Array]:
    """Step-fused segment store: A same-shape SoA groups, ONE launch.
    Returns one AoS array per group."""
    if not isinstance(spec, Segment):
        raise TypeError("scatter_many needs a Segment spec")
    pol = resolve(policy)
    groups = [list(g) for g in groups]
    nf = spec.fields
    spec = spec.bind(groups[0][0].dtype)
    prog = _program.fuse([_lower.lower("seg.int", spec, pol.impl,
                                       shard)] * len(groups))
    fn = _lower.executor(prog, (spec,) * len(groups), shard)
    if len(groups) == 1:
        return [fn(groups[0])]
    stacked = [jnp.stack([g[f] for g in groups]) for f in range(nf)]
    out = fn(stacked)
    return [out[a] for a in range(len(groups))]


# ---------------------------------------------------------------------------
# warm-up: precompile the plan bank for a window width
# ---------------------------------------------------------------------------

def warm(n: int, *, offset: int = 0, vl: int | None = None,
         strided: bool = True, fields: tuple | None = None,
         policy: Policy | str | None = None) -> None:
    """Precompile runtime-stride bank plans and segment plans for a window
    width (one-time host cost, so the first step never pays plan
    compilation).  ``strided=False`` skips the +-stride slots — serving
    only consults the segment plans (the KV FIELD=2 split).

    Resolves ``policy`` exactly like the verbs (explicit arg > innermost
    ``vx.use`` scope > env/platform default), so prewarming compiles the
    plans the governing policy will actually hit: bank slots are warmed
    only when the policy carries a non-empty ``bank_strides`` set (the
    bank itself always compiles the full :data:`~repro.vx.policy.
    BANK_STRIDES` slot layout — its ``lax.switch`` shape is fixed), and
    segment plans are skipped entirely under ``impl="ref"`` (the XLA path
    never consults them)."""
    from repro.core import accessfuse
    from repro.vx.policy import BANK_FIELDS
    pol = resolve(policy)
    fields = BANK_FIELDS if fields is None else fields
    if pol.impl == "ref":
        fields = ()
    accessfuse.warm(n, offset=offset, vl=vl,
                    strided=strided and bool(pol.bank_strides),
                    fields=fields)
