"""Policy-driven lowering of vx verbs onto the EARTH kernel stack.

This is the ONE routing layer between the declarative API
(``spec + verb + policy``) and the mechanism modules:

* ``kernels/ref.py``       — pure-jnp oracles (impl="ref", the XLA path),
* ``kernels/strided.py``   — compiled-plan / dynamic-count Pallas kernels,
* ``kernels/segment.py``   — fused segment-transposition kernels,
* ``kernels/moe_compact.py`` and ``kernels/shift_{gather,scatter}.py``,
* ``core/accessfuse.py``   — runtime-stride plan bank + compaction counts.

Every static-pattern verb resolves through an *executor* memoized in the
unified plan cache (``repro.vx.cache.PLANS``) under the spec's full key —
which includes dtype and vl — so plans and lowered closures are compiled
once per (spec, impl) and can never collide across element types.

Nothing here imports ``kernels/ops.py`` or ``core/drom.py``: those are the
deprecated shims, and they delegate *to* this module.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.vx.cache import PLANS
from repro.vx.policy import Policy, resolve
from repro.vx.spec import (BANK, AccessSpec, Compact, Indexed, Segment,
                           Strided)


def _is_static(stride) -> bool:
    return isinstance(stride, (int, np.integer))


def _executor(tag: str, spec: AccessSpec, impl: str, builder):
    return PLANS.get(("exec", tag, *spec.key(), impl), builder)


# ---------------------------------------------------------------------------
# gather / scatter (Strided, Indexed)
# ---------------------------------------------------------------------------

def _static_strided(spec: Strided, stride) -> Strided | None:
    """The spec with a compile-time stride folded in, or None if the
    stride is runtime (traced)."""
    if not spec.runtime:
        if stride is not None:
            raise ValueError(
                f"stride= was passed but {spec} already pins stride="
                f"{spec.stride}; use stride=vx.BANK in the spec for "
                f"call-time strides")
        return spec
    if stride is None:
        raise ValueError(
            "spec has stride=vx.BANK: pass the runtime stride as stride=")
    if _is_static(stride):
        return dataclasses.replace(spec, stride=int(stride))
    return None


def _gather_strided_exec(spec: Strided, impl: str):
    s, o, vl = spec.stride, spec.offset, spec.vl

    def build():
        if s < 0:
            from repro.core import accessfuse
            return lambda w: accessfuse.bank_gather_strided(w, s, o, vl)
        if impl == "ref":
            from repro.kernels import ref
            return lambda w: ref.gather_strided(w, s, o, vl)
        from repro.kernels import strided
        return lambda w: strided.gather_strided(w, s, o, vl,
                                                compiled=impl == "pallas")

    return _executor("gather", spec, impl if s > 0 else "bank", build)


def _scatter_strided_exec(spec: Strided, impl: str):
    s, o = spec.stride, spec.offset

    def build():
        if s < 0:
            from repro.core import accessfuse
            return lambda w, v: accessfuse.bank_scatter_strided(w, v, s, o)
        if impl == "ref":
            from repro.kernels import ref
            return lambda w, v: ref.scatter_strided(w, v, s, o)
        from repro.kernels import strided
        return lambda w, v: strided.scatter_strided(
            w, v, s, o, compiled=impl == "pallas")

    return _executor("scatter", spec, impl if s > 0 else "bank", build)


def gather(spec: AccessSpec, buf: jax.Array, *, stride=None, shift=None,
           valid=None, policy: Policy | str | None = None) -> jax.Array:
    """Dense read through the access described by ``spec``.

    * :class:`Strided` — ``(..., n) -> (..., vl)``; a ``stride=vx.BANK``
      spec takes the runtime stride via ``stride=`` and dispatches through
      the plan bank's ``lax.switch`` (compiled masks for banked strides,
      dynamic-count network otherwise; either sign engages the Reverser).
    * :class:`Indexed` — raw DROM gather with explicit per-lane ``shift``
      and ``valid`` operands.
    """
    pol = resolve(policy)
    if isinstance(spec, Strided):
        spec = spec.bind(buf.dtype)
        static = _static_strided(spec, stride)
        if static is not None:
            return _gather_strided_exec(static, pol.impl)(buf)
        from repro.core import accessfuse
        return accessfuse.bank_gather_strided(buf, stride, spec.offset,
                                              spec.vl)
    if isinstance(spec, Indexed):
        if shift is None or valid is None:
            raise ValueError("Indexed gather needs shift= and valid=")
        if pol.impl == "ref":
            from repro.core import shiftnet
            res = shiftnet.gather_network(buf, shift, valid, axis=-1)
            return jnp.where(res.valid, res.payload,
                             jnp.zeros_like(res.payload))
        from repro.kernels import shift_gather as _sg
        return _sg.shift_gather(buf, shift, valid)
    raise TypeError(f"gather does not accept {type(spec).__name__} specs")


def scatter(spec: AccessSpec, buf: jax.Array, values: jax.Array, *,
            stride=None, shift=None, valid=None,
            policy: Policy | str | None = None):
    """Write/merge through the access described by ``spec``.

    * :class:`Strided` — merge dense ``values`` into strided positions of
      ``buf`` (read-modify-write; returns the updated window).
    * :class:`Indexed` — raw DROM scatter of ``values`` (``buf`` is unused;
      pass None); returns ``(payload, occupancy)``.
    * :class:`Compact` — expansion (the compaction inverse): ``buf`` is the
      boolean mask, ``values`` the packed rows; returns rows scattered back
      to the mask positions, zeros elsewhere.
    """
    pol = resolve(policy)
    if isinstance(spec, Strided):
        spec = spec.bind(buf.dtype)
        static = _static_strided(spec, stride)
        if static is not None:
            return _scatter_strided_exec(static, pol.impl)(buf, values)
        from repro.core import accessfuse
        return accessfuse.bank_scatter_strided(buf, values, stride,
                                               spec.offset)
    if isinstance(spec, Indexed):
        if shift is None or valid is None:
            raise ValueError("Indexed scatter needs shift= and valid=")
        if pol.impl == "ref":
            from repro.core import shiftnet
            res = shiftnet.scatter_network(values, shift, valid, axis=-1)
            return (jnp.where(res.valid, res.payload,
                              jnp.zeros_like(res.payload)),
                    jnp.broadcast_to(res.valid, values.shape))
        from repro.kernels import shift_scatter as _ss
        return _ss.shift_scatter(values, shift, valid)
    if isinstance(spec, Compact):
        if pol.impl == "ref":
            from repro.kernels import ref
            return ref.expand_rows(values, buf)
        from repro.kernels import moe_compact
        return moe_compact.expand_rows(values, buf)
    raise TypeError(f"scatter does not accept {type(spec).__name__} specs")


# ---------------------------------------------------------------------------
# transpose (Segment): AoS <-> SoA
# ---------------------------------------------------------------------------

def _deinterleave_exec(spec: Segment, impl: str):
    fields = spec.fields

    def build():
        if impl == "ref":
            from repro.kernels import ref
            return lambda a: ref.deinterleave(a, fields)
        from repro.kernels import segment
        return lambda a: segment.deinterleave(a, fields,
                                              fused=impl == "pallas")

    return _executor("deint", spec, impl, build)


def _interleave_exec(spec: Segment, impl: str):
    def build():
        if impl == "ref":
            from repro.kernels import ref
            return lambda parts: ref.interleave(parts)
        from repro.kernels import segment
        return lambda parts: segment.interleave(parts,
                                                fused=impl == "pallas")

    return _executor("int", spec, impl, build)


def transpose(spec: Segment, x, *, policy: Policy | str | None = None):
    """Segment transposition, direction inferred from the operand:

    * a single AoS array ``(..., n)`` -> list of ``fields`` SoA arrays
      ``(..., n/fields)`` (segment load / deinterleave),
    * a sequence of ``fields`` SoA arrays -> one AoS array (segment store /
      interleave).
    """
    if not isinstance(spec, Segment):
        raise TypeError(f"transpose needs a Segment spec, got "
                        f"{type(spec).__name__}")
    pol = resolve(policy)
    if isinstance(x, (list, tuple)):
        parts = list(x)
        if len(parts) != spec.fields:
            raise ValueError(f"expected {spec.fields} fields, "
                             f"got {len(parts)}")
        spec = spec.bind(parts[0].dtype)
        return _interleave_exec(spec, pol.impl)(parts)
    if x.shape[-1] != spec.n:
        raise ValueError(f"AoS beat has {x.shape[-1]} lanes, spec.n is "
                         f"{spec.n}")
    spec = spec.bind(x.dtype)
    return _deinterleave_exec(spec, pol.impl)(x)


# ---------------------------------------------------------------------------
# compact (Compact): masked compaction / packed indices
# ---------------------------------------------------------------------------

def compact(spec: Compact, mask: jax.Array, rows: jax.Array | None = None,
            *, policy: Policy | str | None = None):
    """Order-preserving masked compaction.

    With ``rows`` — pack the masked rows to the front; returns
    ``(packed_rows, packed_valid)``, truncated to ``spec.capacity`` rows
    when ``cap`` is set.  Without ``rows`` — return the packed *indices*
    of set mask bits (first ``spec.capacity`` kept), the MoE dispatch
    primitive (runtime-count plan-bank member; no conflict reductions)."""
    if not isinstance(spec, Compact):
        raise TypeError(f"compact needs a Compact spec, got "
                        f"{type(spec).__name__}")
    pol = resolve(policy)  # validate even on the impl-independent path
    if rows is None:
        from repro.core import accessfuse
        return accessfuse.compact_indices(mask, spec.capacity)
    if pol.impl == "ref":
        from repro.kernels import ref
        packed, valid = ref.compact_rows(rows, mask)
    else:
        from repro.kernels import moe_compact
        packed, valid = moe_compact.compact_rows(rows, mask)
    cap = spec.capacity
    if cap < packed.shape[0]:
        packed = jax.lax.slice_in_dim(packed, 0, cap, axis=0)
        valid = jax.lax.slice_in_dim(valid, 0, cap, axis=0)
    return packed, valid


# ---------------------------------------------------------------------------
# batched forms: one launch for a whole step's same-shape accesses
# ---------------------------------------------------------------------------

def gather_many(specs, bufs, *, policy: Policy | str | None = None):
    """Whole-step batched gather — ONE kernel launch, one mask operand.

    * ``specs`` a sequence of :class:`Strided` sharing (n, vl) with
      per-access (stride, offset), ``bufs`` the matching windows (a
      sequence, or an already-stacked ``(A, ..., n)`` array): the fused
      concatenated-mask kernel.  Returns the stacked ``(A, ..., vl)``.
    * ``specs`` a single :class:`Segment`, ``bufs`` a sequence of
      same-shape AoS arrays: the step-fused segment load.  Returns one
      field list per input array.
    """
    pol = resolve(policy)
    if isinstance(specs, Segment):
        aos_list = list(bufs)
        spec = specs.bind(aos_list[0].dtype)
        if pol.impl != "ref":
            from repro.kernels import segment
            return segment.deinterleave_many(aos_list, spec.fields,
                                             fused=pol.impl == "pallas")
        outs = transpose(spec, jnp.stack(aos_list), policy=pol)
        return [[o[a] for o in outs] for a in range(len(aos_list))]
    specs = list(specs)
    if not specs or not all(isinstance(s, Strided) for s in specs):
        raise TypeError("gather_many needs Strided specs or one Segment")
    vls = {s.vl for s in specs}
    if len(vls) != 1 or len({s.n for s in specs}) != 1:
        raise ValueError("fused gather needs one shared (n, vl)")
    vl = vls.pop()
    windows = bufs if isinstance(bufs, jax.Array) else jnp.stack(list(bufs))
    pairs = tuple((s.stride, s.offset) for s in specs)
    if pol.impl == "ref":
        from repro.kernels import ref
        return jnp.stack([ref.gather_strided(windows[a], s, o, vl)
                          for a, (s, o) in enumerate(pairs)])
    from repro.kernels import strided
    return strided.gather_strided_fused(windows, pairs, vl,
                                        compiled=pol.impl == "pallas")


def scatter_many(spec: Segment, groups: Sequence[Sequence[jax.Array]], *,
                 policy: Policy | str | None = None) -> list[jax.Array]:
    """Step-fused segment store: A same-shape SoA groups, ONE launch.
    Returns one AoS array per group."""
    if not isinstance(spec, Segment):
        raise TypeError("scatter_many needs a Segment spec")
    pol = resolve(policy)
    groups = [list(g) for g in groups]
    nf = spec.fields
    if len(groups) == 1:
        return [transpose(spec, groups[0], policy=pol)]
    stacked = [jnp.stack([g[f] for g in groups]) for f in range(nf)]
    out = transpose(spec.bind(stacked[0].dtype), stacked, policy=pol)
    return [out[a] for a in range(len(groups))]


# ---------------------------------------------------------------------------
# warm-up: precompile the plan bank for a window width
# ---------------------------------------------------------------------------

def warm(n: int, *, offset: int = 0, vl: int | None = None,
         strided: bool = True, fields: tuple | None = None) -> None:
    """Precompile runtime-stride bank plans and segment plans for a window
    width (one-time host cost, so the first step never pays plan
    compilation).  ``strided=False`` skips the +-stride slots — serving
    only consults the segment plans (the KV FIELD=2 split)."""
    from repro.core import accessfuse
    from repro.vx.policy import BANK_FIELDS
    accessfuse.warm(n, offset=offset, vl=vl, strided=strided,
                    fields=BANK_FIELDS if fields is None else fields)
