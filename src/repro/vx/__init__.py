"""repro.vx — the declarative vector-access API (EARTH's one datapath).

The paper's core claim is a *single* architectural path for all vector
memory access: strided gather/scatter, segment transposition, and
compaction all route through one coalescer + shift network.  ``vx`` is
that claim as an API — one spec type, four verbs, one policy:

    from repro import vx

    spec = vx.Strided(n=64, stride=4, offset=2, vl=8)
    dense = vx.gather(spec, window)                    # strided load
    win2  = vx.scatter(spec, window, dense)            # strided store

    k, v  = vx.transpose(vx.Segment(n=2 * d, fields=2), kv_beat)
    beat  = vx.transpose(vx.Segment(n=2 * d, fields=2), [k, v])

    packed, pv = vx.compact(vx.Compact(n=T), mask, rows)
    ids        = vx.compact(vx.Compact(n=T, cap=C), mask)   # MoE dispatch

    # runtime (traced) stride -> plan-bank lax.switch dispatch
    out = vx.gather(vx.Strided(n=64, stride=vx.BANK, vl=8), win, stride=s)

    # whole-step batched forms (one launch, one mask operand)
    outs = vx.gather_many([spec_a, spec_b], windows)
    kvs  = vx.gather_many(vx.Segment(n=2 * d, fields=2), kv_caches)

    # paged KV pool: geometry is compiled state, the page table is a
    # runtime operand (one cached program serves every request)
    pg   = vx.Paged(page_size=16, pages=8, trail=2)
    seqs = vx.gather(pg, pool, table=tables)             # paged read
    pool = vx.scatter(pg, pool, beats, table=tables, pos=pos)  # append
    alls = vx.gather_many(pg, pools, table=tables)       # ONE program

Lowering is policy-driven, never a per-call ``impl=`` string:

    with vx.use("pallas"):          # or vx.use(Policy(...)) / env default
        ...                         # every verb in scope lowers to Pallas

Resolution order: explicit ``policy=`` arg > innermost ``vx.use`` scope >
``vx.Policy.default()`` (the ``REPRO_VX_IMPL`` env var, else platform).
Plans and lowered executors are memoized in ONE spec-keyed LRU
(:data:`vx.PLANS`) whose keys include dtype and vl.

Every verb lowers through ONE explicit pipeline (PR 4):
**spec** (frozen AccessSpec) -> **plan** (compiled shift plans,
core/shiftplan.py) -> **program** (routed transactions with placement
annotations, ``vx.program``).  Passing ``shard=vx.Shard(axes, axis,
mesh)`` lowers the access shard-locally under ``shard_map`` — per-shard
offset-rebased plans for strided patterns, local lane permutation for
segment transposition — so a sharded buffer is never sliced globally.
Compiled programs are memoized in ``vx.PLANS`` under keys that include
dtype, vl, impl AND the shard layout.

The legacy entry points (``kernels/ops.py``, ``core/drom.py``) survive as
deprecated shims delegating here; internal code must not use them (CI
escalates the shims' DeprecationWarnings to errors).
"""
from repro.vx import lower, program
from repro.vx._dispatch import (compact, gather, gather_many, scatter,
                                scatter_many, transpose, warm)
from repro.vx.cache import PLANS, PlanCache
from repro.vx.policy import (BANK_FIELDS, BANK_STRIDES, IMPLS,
                             MIN_FUSED_ELEMS, Policy, current, resolve, use)
from repro.vx.program import Program, Shard, Txn
from repro.vx.spec import (BANK, AccessSpec, Compact, Indexed, Paged,
                           Segment, Strided)

__all__ = [
    "AccessSpec", "Strided", "Segment", "Indexed", "Compact", "Paged",
    "BANK",
    "gather", "scatter", "transpose", "compact", "gather_many",
    "scatter_many", "warm",
    "Policy", "use", "current", "resolve",
    "PLANS", "PlanCache",
    "Shard", "Program", "Txn", "program", "lower",
    "MIN_FUSED_ELEMS", "BANK_STRIDES", "BANK_FIELDS", "IMPLS",
]
