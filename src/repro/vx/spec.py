"""AccessSpec — the frozen, hashable sum type describing a vector access.

One spec fully determines a memory-access *pattern*: window width, stride
(static Python int or the runtime :data:`BANK` sentinel), offset, vector
length, field count, and element dtype.  Specs are pure data — hashable,
comparable, and usable as plan-cache keys — so the dispatch layer
(``repro.vx._dispatch``) can compile/look up a routing plan once per spec
and the policy layer can pick a lowering without inspecting arrays.

Four constructors (EARTH's four access archetypes):

* :class:`Strided`  — ``out[i] = window[offset + i*stride]`` (LSDO / DROM
  strided gather-scatter; ``stride=BANK`` defers the stride to call time
  and routes through the runtime-stride plan bank).
* :class:`Segment`  — AoS <-> SoA field transposition over an ``n``-lane
  beat with ``fields`` interleaved fields (RCVRF segment access).
* :class:`Indexed`  — shift-network access driven by per-lane (shift,
  valid) routing (the DROM primitive under everything else).  Host-known
  routings fold into the spec (``routing=``) and compile to constant
  take-masks through the plan stage; traced routings keep the dynamic
  network.
* :class:`Compact`  — order-preserving masked compaction (the MoE dispatch
  primitive) and its expansion inverse.
* :class:`Paged`    — page-table-indexed gather/append over a shared page
  pool (the serving KV-cache pattern): page geometry is static and keys
  the compiled program; the page table is a runtime operand, so ONE cached
  program serves every request.

``dtype`` and ``vl`` participate in ``key()`` — plan-cache entries can
therefore never collide across element types or vector lengths (the PR 3
cache-collision fix; regression-tested in tests/test_vx_api.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any


class _Bank:
    """Singleton marker: stride is a runtime (possibly traced) value."""

    _instance: "_Bank | None" = None

    def __new__(cls) -> "_Bank":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "vx.BANK"


#: Pass as ``Strided.stride`` to defer the stride to call time.  Static
#: in-bank strides compile to constant-mask plans behind one ``lax.switch``;
#: everything else takes the dynamic-count network (bit-exact).
BANK = _Bank()


def _dtype_str(dtype: Any) -> str | None:
    if dtype is None:
        return None
    import numpy as np

    return str(np.dtype(dtype))


class AccessSpec:
    """Mixin shared by the four spec dataclasses (not instantiable)."""

    def key(self) -> tuple:
        """Hashable cache key: class name + every field, BANK normalized."""
        vals = []
        for f in dataclasses.fields(self):  # type: ignore[arg-type]
            v = getattr(self, f.name)
            vals.append("bank" if v is BANK else v)
        return (type(self).__name__, *vals)

    def bind(self, dtype: Any) -> "AccessSpec":
        """Spec with the element dtype filled in (no-op if already set).

        Dispatch binds the payload's dtype before any cache lookup, so two
        accesses that differ only in element type can never share a plan
        entry."""
        if getattr(self, "dtype", None) is not None:
            return self
        return dataclasses.replace(self, dtype=_dtype_str(dtype))


@dataclasses.dataclass(frozen=True)
class Strided(AccessSpec):
    """``out[..., i] = window[..., offset + i*stride]`` for i < vl.

    ``stride`` is a static Python int (either sign; negative engages the
    §3.2.2 Reverser) or :data:`BANK` (runtime stride, supplied to the verb
    as ``stride=``).
    """

    n: int
    stride: Any                 # int | BANK
    vl: int
    offset: int = 0
    dtype: str | None = None

    def __post_init__(self):
        object.__setattr__(self, "dtype", _dtype_str(self.dtype))
        if self.vl < 0:
            raise ValueError(f"vl must be >= 0, got {self.vl}")
        s = self.stride
        if s is BANK:
            return
        s = int(s)
        object.__setattr__(self, "stride", s)
        if s == 0:
            raise ValueError("stride 0 is a broadcast, not a strided access")
        if self.vl == 0:
            return
        last = self.offset + (self.vl - 1) * s
        lo, hi = (last, self.offset) if s < 0 else (self.offset, last)
        if lo < 0 or hi >= self.n:
            raise ValueError(
                f"strided access [{lo}, {hi}] leaves the {self.n}-lane "
                f"window: {self}")

    @property
    def runtime(self) -> bool:
        return self.stride is BANK


@dataclasses.dataclass(frozen=True)
class Segment(AccessSpec):
    """AoS beat of ``n`` lanes <-> ``fields`` SoA fields of ``n/fields``."""

    n: int
    fields: int
    dtype: str | None = None

    def __post_init__(self):
        object.__setattr__(self, "dtype", _dtype_str(self.dtype))
        if self.fields < 1 or self.n % self.fields:
            raise ValueError(
                f"segment needs n divisible by fields, got {self}")

    @property
    def field_len(self) -> int:
        return self.n // self.fields


@dataclasses.dataclass(frozen=True)
class Indexed(AccessSpec):
    """DROM access over ``n`` lanes routed by per-lane (shift, valid).

    Two forms (no closed-form SCG in either):

    * dynamic — ``shift``/``valid`` are traced call-time operands and the
      access pays the dynamic-count network;
    * static  — a host-known routing is folded into the spec as
      ``routing=(shifts, valids)`` (hashable tuples), which PROMOTES the
      access into the plan stage: the layer take-masks are computed once
      at executor build and memoized in ``vx.PLANS`` under this spec's
      key, so the payload pays one static shift + one select per layer
      (the same promotion the verbs apply automatically when they receive
      concrete numpy routing operands).

    The routing must be GSN-safe (order-preserving, separation
    non-increasing toward lane 0) — the same contract as the dynamic
    network.
    """

    n: int
    dtype: str | None = None
    routing: tuple | None = None   # ((shift,)*n, (valid,)*n) host constants

    def __post_init__(self):
        object.__setattr__(self, "dtype", _dtype_str(self.dtype))
        if self.routing is not None:
            shifts, valids = self.routing
            shifts = tuple(int(s) for s in shifts)
            valids = tuple(bool(v) for v in valids)
            if len(shifts) != self.n or len(valids) != self.n:
                raise ValueError(
                    f"routing must carry {self.n} per-lane entries, got "
                    f"{len(shifts)}/{len(valids)}")
            object.__setattr__(self, "routing", (shifts, valids))

    @property
    def static(self) -> bool:
        return self.routing is not None


@dataclasses.dataclass(frozen=True)
class Paged(AccessSpec):
    """Page-table-indexed access over a shared pool (paged KV cache).

    The pool holds ``(*lead, P, page_size, *trail)`` with ``trail`` static
    trailing dims after the in-page axis (a KV pool ``(NS, P, ps, K, 2D)``
    has ``trail=2``); the page table is a RUNTIME int32 operand
    ``(*batch, pages)`` mapping each sequence's logical pages to physical
    pool pages, ``-1`` marking unallocated entries (gather returns zeros
    there; scatter drops writes).

    * gather  — ``out[..., j, ...] = pool[..., table[j // ps], j % ps,
      ...]`` for j < pages*ps: the per-request page-table gather, one
      take at page granularity (beats stay contiguous — the coalesced
      EARTH transaction), table-driven and reusable across requests.
    * scatter — the decode append: one ``(*batch, *trail)`` beat written
      at per-row position ``pos`` through the table (rows with ``pos < 0``
      or an unallocated page are dropped).

    Only the page GEOMETRY is spec data — page_size, table width, trail
    rank, dtype — so the compiled program is keyed by page size (one plan
    per geometry, shared by every request and every decode step), never by
    the runtime table.

    ``scale_dtype`` selects the QUANTIZED pool variant: the pool stores
    int8/fp8 values and a per-page side tensor of symmetric max-abs
    scales (``(*lead, P, *trail[:-1])`` — per page, per head, shared
    over the last trail dim) rides every gather/scatter as an extra
    operand.  Gather dequantizes in the same program (the scale gather
    is a one-hot contraction — no extra launch); scatter quantizes the
    beat on write and monotonically WIDENS the page scale (rescaling
    resident ints), so shared CoW prefix pages never need rewriting.
    ``scale_dtype`` is a spec field, so the quantized program is a
    distinct plan-cache entry from the float one automatically.
    """

    page_size: int
    pages: int                     # static table width (pages per sequence)
    trail: int = 0                 # trailing dims after the in-page axis
    dtype: str | None = None
    scale_dtype: str | None = None  # set => quantized pool (+scales operand)

    def __post_init__(self):
        object.__setattr__(self, "dtype", _dtype_str(self.dtype))
        object.__setattr__(self, "scale_dtype", _dtype_str(self.scale_dtype))
        if self.page_size < 1 or self.pages < 1 or self.trail < 0:
            raise ValueError(f"bad paged geometry: {self}")

    @property
    def quantized(self) -> bool:
        return self.scale_dtype is not None

    @property
    def seq_len(self) -> int:
        """Gathered logical length: pages * page_size."""
        return self.pages * self.page_size

    def pool_axis(self, ndim: int) -> int:
        """Index of the pool's page axis for a rank-``ndim`` operand
        (negative-from-end ``-(trail + 2)``, so it survives fusion-pass
        stacking of pools along a new leading dim)."""
        ax = ndim - 2 - self.trail
        if ax < 0:
            raise ValueError(
                f"rank-{ndim} pool cannot carry (P, page_size) plus "
                f"{self.trail} trailing dims: {self}")
        return ax


@dataclasses.dataclass(frozen=True)
class Compact(AccessSpec):
    """Order-preserving masked compaction over ``n`` rows (MoE dispatch).

    ``cap`` bounds the packed output length (defaults to ``n``)."""

    n: int
    cap: int | None = None
    dtype: str | None = None

    def __post_init__(self):
        object.__setattr__(self, "dtype", _dtype_str(self.dtype))

    @property
    def capacity(self) -> int:
        return self.n if self.cap is None else min(self.cap, self.n)
