"""vx.Policy — the single knob stack for vector-access lowering.

PRs 1-2 grew three uncoordinated ways to choose a lowering: per-call
``impl=`` strings threaded through every layer, ``core/drom.default_impl``'s
platform probe, and the scheduler's module-level fusion/platform constants.
This module replaces all of them with one frozen :class:`Policy` resolved in
priority order:

1. an explicit ``policy=`` argument on a verb (a Policy, or an impl string
   as shorthand),
2. the innermost ``with vx.use(...)`` context (thread-local, nestable,
   exception-safe),
3. :meth:`Policy.default` — the ``REPRO_VX_IMPL`` environment variable,
   else the platform default (``pallas`` on TPU, ``ref`` elsewhere).

Everything tunable about dispatch lives on the Policy: the impl family,
the scheduler's fusion threshold (below which a merged group rides the XLA
path instead of paying a kernel launch), the runtime-stride bank contents,
and whether the platform lowering rule (off-TPU merged groups lower to
XLA) applies.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import os
import threading

#: Below this many elements a merged group is inlined on the XLA path
#: instead of paying a kernel launch (decode-time single-token beats).
MIN_FUSED_ELEMS = 1 << 15

#: What the runtime-stride plan bank precompiles: strides +-1..8 (the
#: negative half via the Reverser) and the segment field counts occurring
#: in this repo's models/data paths.
BANK_STRIDES = tuple(range(1, 9))
BANK_FIELDS = (2, 4)

IMPLS = ("ref", "pallas", "pallas_dynamic")

ENV_VAR = "REPRO_VX_IMPL"


def _platform() -> str:
    try:
        import jax

        return jax.devices()[0].platform
    except Exception:  # pragma: no cover
        return "cpu"


@dataclasses.dataclass(frozen=True)
class Policy:
    """How vx verbs lower.  Frozen and hashable (usable in cache keys)."""

    impl: str = "ref"                       # ref | pallas | pallas_dynamic
    fusion_threshold: int = MIN_FUSED_ELEMS
    bank_strides: tuple = BANK_STRIDES
    platform_lowering: bool = True          # off-TPU merged groups -> XLA

    def __post_init__(self):
        if self.impl not in IMPLS:
            raise ValueError(
                f"unknown impl {self.impl!r} (want one of {IMPLS})")
        object.__setattr__(self, "bank_strides", tuple(self.bank_strides))

    @staticmethod
    def default() -> "Policy":
        """Process-wide default: ``REPRO_VX_IMPL`` env var, else platform
        (``pallas`` on TPU, ``ref`` elsewhere).  This is the ONE resolution
        point — ``core/drom.default_impl`` and ``ModelConfig.kernel_impl``
        both route here, so one knob controls the whole stack."""
        return _default_policy(os.environ.get(ENV_VAR), _platform())

    def with_impl(self, impl: str | None) -> "Policy":
        if impl is None or impl == self.impl:
            return self
        return dataclasses.replace(self, impl=impl)

    def for_elems(self, total_elems: int) -> "Policy":
        """Scheduler launch policy: accesses below the fusion threshold
        ride the XLA path (a scheduler does not issue a wide transaction
        for one beat)."""
        if self.impl == "ref" or total_elems >= self.fusion_threshold:
            return self
        return dataclasses.replace(self, impl="ref")


@functools.lru_cache(maxsize=None)
def _default_policy(env_impl: str | None, platform: str) -> Policy:
    impl = env_impl or ("pallas" if platform == "tpu" else "ref")
    return Policy(impl=impl)


_tls = threading.local()


def _stack() -> list:
    s = getattr(_tls, "stack", None)
    if s is None:
        s = _tls.stack = []
    return s


def current() -> Policy:
    """The active policy: innermost ``vx.use`` scope, else the default.

    NOTE: verbs read this at TRACE time.  A function already traced by
    ``jax.jit`` keeps the lowering it was traced with — changing the
    ambient policy (or ``REPRO_VX_IMPL``) later does not re-trace it.
    Pin ``policy=`` explicitly (or re-jit) when a call site must follow a
    policy that changes within the process."""
    s = _stack()
    return s[-1] if s else Policy.default()


def resolve(policy: "Policy | str | None" = None) -> Policy:
    """Normalize a verb's ``policy=`` argument.

    ``None`` -> the active policy; an impl string -> the active policy with
    that impl (shorthand easing migration from ``impl=`` call sites); a
    :class:`Policy` -> itself."""
    if policy is None:
        return current()
    if isinstance(policy, str):
        return current().with_impl(policy)
    if isinstance(policy, Policy):
        return policy
    raise TypeError(f"policy must be Policy | str | None, got {policy!r}")


@contextlib.contextmanager
def use(policy: "Policy | str | None" = None, **overrides):
    """Scope a policy: ``with vx.use("pallas"): ...`` or
    ``with vx.use(fusion_threshold=0): ...``.  Nests; the previous policy
    is restored on exit (including on exceptions)."""
    base = resolve(policy)
    pol = dataclasses.replace(base, **overrides) if overrides else base
    s = _stack()
    s.append(pol)
    try:
        yield pol
    finally:
        s.pop()
