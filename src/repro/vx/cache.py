"""The unified spec-keyed LRU plan cache.

Before PR 3, compiled routing state was memoized in three unrelated places:
``functools.lru_cache`` on every plan constructor in ``core/shiftplan.py``,
a second pair of ``lru_cache`` banks in ``core/accessfuse.py``, and ad-hoc
executor closures rebuilt per call in ``kernels/``.  All of it now lives in
ONE bounded LRU (:data:`PLANS`) keyed by tagged tuples — dispatch-level
entries are keyed by ``AccessSpec.key()`` which includes dtype and vl, so
entries can never collide across element types (the PR 3 cache-collision
fix).

Import discipline: this module must stay dependency-free (stdlib only) —
``core/shiftplan.py`` and ``core/accessfuse.py`` import it at module scope.
"""
from __future__ import annotations

import collections
import functools
import threading
from typing import Any, Callable


class PlanCache:
    """Thread-safe bounded LRU.  ``get`` builds on miss.

    The builder runs OUTSIDE the lock: plan compilation can be expensive
    (a Benes decomposition is host-side NumPy) and builders recurse into
    the cache (segment strategy plans consult per-field plans), so holding
    the lock across a build would serialize every concurrent access.  Two
    threads racing the same miss may both build; the first insert wins
    (plans are deterministic pure data, so the duplicate is discarded)."""

    def __init__(self, maxsize: int = 4096):
        self.maxsize = maxsize
        self._data: "collections.OrderedDict[tuple, Any]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: tuple, builder: Callable[[], Any]) -> Any:
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return self._data[key]
            self.misses += 1
        value = builder()
        with self._lock:
            if key in self._data:          # lost a build race: keep first
                self._data.move_to_end(key)
                return self._data[key]
            self._data[key] = value
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)
                self.evictions += 1
            return value

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: tuple) -> bool:
        with self._lock:
            return key in self._data

    def keys(self) -> list[tuple]:
        with self._lock:
            return list(self._data)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self.hits = self.misses = self.evictions = 0

    def stats(self) -> dict:
        """Hit/miss/evict counters — the steady-state health check: a
        serving loop that keeps missing after warmup is recompiling plans
        every step (an unstable cache key), which tests/test_serve.py
        asserts against."""
        with self._lock:
            return {"size": len(self._data), "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions,
                    "maxsize": self.maxsize}


#: The process-wide plan cache: shift plans, plan banks, segment strategy
#: picks, and vx executor closures all live here.
PLANS = PlanCache()


def memoize(kind: str) -> Callable:
    """Decorator replacing per-function ``functools.lru_cache`` for plan
    constructors: entries land in :data:`PLANS` under ``(kind, *args)``.
    Positional args must be hashable (plan constructors take only ints and
    tuples); keyword args are folded in sorted order."""

    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            key = (kind, *args)
            if kwargs:
                key += tuple(sorted(kwargs.items()))
            return PLANS.get(key, lambda: fn(*args, **kwargs))

        wrapper.cache = PLANS  # type: ignore[attr-defined]
        return wrapper

    return deco
