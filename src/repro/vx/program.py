"""The access-program IR — stage three of the vx pipeline.

Since PR 4 every vx verb lowers through an explicit three-stage pipeline:

    spec     (vx/spec.py)     WHAT is accessed — the frozen AccessSpec,
    plan     (core/shiftplan) HOW lanes route — compiled shift plans /
                              runtime plan banks,
    program  (this module)    WHAT EXECUTES, AND WHERE — a small list of
                              routed transactions with placement
                              annotations.

A :class:`Program` is pure data: a tuple of :class:`Txn` (routed
transactions).  Each Txn names the executing operation (``op``), the spec
keys it serves (``specs`` — more than one when a step-level fusion pass
merged accesses into one super-transaction), the resolved lowering
(``impl``), and a placement (``layout`` — ``None`` for replicated
execution, or a :meth:`Shard.layout` tuple for shard-local execution under
``shard_map``).

Programs are hashable and feed the unified plan cache: the compiled
executor for a program is memoized in ``vx.PLANS`` under
``Program.key()``, which therefore includes the SHARD LAYOUT — the same
spec lowered against two different placements yields two distinct cached
programs (regression-tested in tests/test_vx_api.py).  This is the SPMD
analogue of Ara's register-file-aware memory datapath: the lowering is
co-designed with how the buffer is physically distributed, instead of
slicing a sharded leaf globally and letting the partitioner rematerialize.

The fusion pass (:func:`fuse`) is how ``accessfuse.StepScheduler``
participates: it merges single-transaction programs over same-shape
accesses into ONE wide transaction (width = number of merged accesses)
instead of maintaining a parallel execution path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

from repro.vx.spec import AccessSpec


# ---------------------------------------------------------------------------
# Placement
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Shard:
    """Operand placement: buffer axis ``axis`` is sharded over mesh
    ``axes`` (contiguous equal blocks, first axis major — the
    ``PartitionSpec`` split order).

    ``axis`` counts from the END (must be negative) so the annotation
    stays valid when a fusion pass stacks accesses along a new leading
    dim.  ``axis == -1`` shards the ACCESSED lane axis itself — strided
    programs then rebase offsets per shard; any other axis is elementwise
    for lane-permutation programs, which execute shard-locally with the
    unmodified plan.

    ``mesh`` is excluded from dataclass equality/hashing but IS part of
    :meth:`layout` (the cache key): two meshes with the same axis names
    and shard count but different shapes or device assignments must not
    share a compiled executor — the executor closes over the mesh (its
    ``shard_map`` and shard-index flattening), so a shared entry would
    silently execute on the first mesh seen.  ``jax.sharding.Mesh`` is
    hashable and compares by devices + axis names, so equal meshes still
    share one entry.
    """

    axes: tuple
    axis: int
    mesh: Any = dataclasses.field(default=None, compare=False)

    def __post_init__(self):
        object.__setattr__(self, "axes", tuple(self.axes))
        if not self.axes:
            raise ValueError("Shard needs at least one mesh axis")
        if self.axis >= 0:
            raise ValueError(
                f"Shard.axis counts from the end (negative), got "
                f"{self.axis} — a leading-axis index would silently point "
                f"at a different dim once a fusion pass stacks operands")
        if self.mesh is None:
            raise ValueError("Shard needs the executing mesh")

    @property
    def nshards(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.axes)

    def layout(self) -> tuple:
        """The hashable placement key: (axis names, buffer axis, count,
        mesh) — see the class docstring for why the mesh is included."""
        return (self.axes, self.axis, self.nshards, self.mesh)

    def divides(self, dim: int) -> bool:
        return dim % self.nshards == 0


def layout_of(shard: "Shard | None") -> tuple | None:
    return None if shard is None else shard.layout()


# ---------------------------------------------------------------------------
# Transactions and programs
# ---------------------------------------------------------------------------

#: Ops a Txn may name.  ``*.plan`` ops consume compiled shift plans;
#: ``bank.*`` dispatch a runtime stride over the plan bank's lax.switch;
#: ``idx.*`` are the DROM network (dynamic counts, or constant take-masks
#: when the spec folds a static routing); ``compact.*`` the MoE
#: primitives; ``paged.*`` page-table-indexed pool access (runtime table
#: operand, program keyed by page geometry only).
OPS = (
    "gather.plan", "scatter.plan", "bank.gather", "bank.scatter",
    "seg.deint", "seg.int", "idx.gather", "idx.scatter",
    "compact.rows", "compact.ids", "compact.expand",
    "paged.gather", "paged.scatter",
)


@dataclasses.dataclass(frozen=True)
class Txn:
    """One routed transaction: op x specs x lowering x placement."""

    op: str
    specs: tuple                  # tuple of AccessSpec.key() tuples
    impl: str
    layout: tuple | None = None   # Shard.layout() | None (replicated)

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"unknown txn op {self.op!r}")
        object.__setattr__(self, "specs", tuple(self.specs))

    @property
    def width(self) -> int:
        """Fused arity: how many accesses this transaction serves."""
        return len(self.specs)

    @property
    def homogeneous(self) -> bool:
        return len(set(self.specs)) == 1


@dataclasses.dataclass(frozen=True)
class Program:
    """A lowered access: the (usually singleton) transaction list."""

    txns: tuple

    def __post_init__(self):
        object.__setattr__(self, "txns", tuple(self.txns))
        if not self.txns:
            raise ValueError("empty program")

    def key(self) -> tuple:
        """The plan-cache key — includes every txn's specs (hence dtype
        and vl) AND its shard layout."""
        return ("prog", self.txns)

    @property
    def txn(self) -> Txn:
        """The single transaction of a 1-txn program."""
        if len(self.txns) != 1:
            raise ValueError(f"program has {len(self.txns)} txns")
        return self.txns[0]

    @property
    def width(self) -> int:
        return sum(t.width for t in self.txns)


def single(op: str, specs: Sequence[AccessSpec] | AccessSpec, impl: str,
           shard: "Shard | None" = None) -> Program:
    """A one-transaction program over ``specs`` (spec objects, keyed)."""
    if isinstance(specs, AccessSpec):
        specs = (specs,)
    return Program((Txn(op, tuple(s.key() for s in specs), impl,
                        layout_of(shard)),))


# ---------------------------------------------------------------------------
# Program-level fusion (the StepScheduler pass)
# ---------------------------------------------------------------------------

def fuse(programs: Sequence[Program]) -> Program:
    """Merge single-transaction programs into ONE wide transaction.

    This is the step scheduler's merge expressed at the program level: the
    N per-access transactions become one transaction of width N (one
    kernel launch, one concatenated mask operand).  All inputs must agree
    on (op, impl, layout); spec compatibility (shared (n, vl) for strided,
    identical specs for segment) is the executor's contract and is
    enforced at compile time in ``vx/lower.py``.
    """
    txns = [p.txn for p in programs]
    heads = {(t.op, t.impl, t.layout) for t in txns}
    if len(heads) != 1:
        raise ValueError(f"cannot fuse mixed transactions: {sorted(heads)}")
    op, impl, layout = heads.pop()
    specs = tuple(s for t in txns for s in t.specs)
    return Program((Txn(op, specs, impl, layout),))
