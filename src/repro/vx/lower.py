"""Lowering — stage transitions of the vx pipeline, and program execution.

``lower()`` takes (op, specs, impl, placement) and emits a validated
:class:`~repro.vx.program.Program`; ``executor()`` compiles a program into
the callable that actually runs it, memoized in the unified plan cache
under ``Program.key()`` — which includes dtype, vl, the resolved impl AND
the shard layout, so the same spec lowered against two placements yields
two distinct cached programs.

Replicated programs lower exactly where the PR 3 dispatch closures did:
``kernels/ref.py`` (XLA oracles), ``kernels/strided.py`` /
``kernels/segment.py`` / ``kernels/moe_compact.py`` /
``kernels/shift_{gather,scatter}.py`` (compiled-plan Pallas), and
``core/accessfuse.py`` (runtime-stride plan bank, compaction counts).

Sharded programs are the new arm: when the operand is sharded on the
accessed axis (``Shard.axis == -1`` for strided patterns) the program is
rewritten to SHARD-LOCAL plans — per-shard offset-rebased sub-specs from
``shiftplan.shard_strided_rows`` — executed under ``shard_map`` with a
``lax.switch`` over the shard index, plus one ``psum`` to merge the
disjoint output lanes (gather) or none at all (scatter: the window stays
sharded).  Lane-permutation programs (segment transposition) sharded on
any OTHER axis execute shard-locally with the unmodified plan.  Either
way the sharded leaf is never sliced globally, so SPMD never
rematerializes it — the lowering is co-designed with the physical
distribution of the buffer, the way Ara co-designs the memory datapath
with the banked register file.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.vx import program as prg
from repro.vx.cache import PLANS
from repro.vx.spec import AccessSpec, Paged, Strided

#: Ops that accept a sharded placement, and where the shard axis may sit.
_SHARDABLE = {
    "gather.plan": "lane",      # Shard.axis == -1: offset-rebased plans
    "scatter.plan": "lane",
    "seg.deint": "outer",       # Shard.axis != -1: shard-local permutation
    "seg.int": "outer",
    "paged.gather": "pool",     # Shard.axis == -(trail+2): the page axis
}


def lower(op: str, specs, impl: str,
          shard: "prg.Shard | None" = None) -> prg.Program:
    """Build and validate the program for one access (width = len(specs))."""
    if isinstance(specs, AccessSpec):
        specs = (specs,)
    specs = tuple(specs)
    if shard is not None:
        where = _SHARDABLE.get(op)
        if where is None:
            raise NotImplementedError(
                f"{op} has no sharded lowering (got shard={shard})")
        if where == "lane":
            if shard.axis != -1:
                raise ValueError(
                    f"{op} shards the accessed lane axis: Shard.axis must "
                    f"be -1, got {shard.axis}")
            for s in specs:
                if s.runtime:
                    raise NotImplementedError(
                        "runtime-stride bank dispatch over a sharded "
                        "window is not lowered; pin the stride or gather "
                        "replicated")
                if not shard.divides(s.n):
                    raise ValueError(
                        f"window of {s.n} lanes does not split into "
                        f"{shard.nshards} equal shards")
            if len(specs) != 1:
                raise NotImplementedError(
                    "fused strided transactions have no sharded lowering")
        elif where == "pool":
            want = -(specs[0].trail + 2)
            if shard.axis != want:
                raise ValueError(
                    f"{op} shards the page-pool axis: Shard.axis must be "
                    f"{want} for trail={specs[0].trail}, got {shard.axis}")
            if len(set(specs)) != 1:
                raise NotImplementedError(
                    "heterogeneous fused paged transactions have no "
                    "sharded lowering")
        elif shard.axis == -1:
            raise ValueError(
                f"{op} permutes the lane axis; shard an outer axis "
                f"(Shard.axis <= -2), not the beat itself")
    return prg.single(op, specs, impl, shard)


def executor(program: prg.Program, specs,
             shard: "prg.Shard | None" = None):
    """The compiled callable for ``program`` (one entry per program key).

    ``specs`` are the live AccessSpec objects in transaction order (the
    program itself carries only their keys); ``shard`` the live placement
    matching the transaction layout.
    """
    if isinstance(specs, AccessSpec):
        specs = (specs,)
    txn = program.txn
    specs = tuple(specs)
    return PLANS.get(program.key(), lambda: _build(txn, specs, shard))


def run(op: str, spec: AccessSpec, impl: str, *operands,
        shard: "prg.Shard | None" = None):
    """lower + compile + execute in one call (the verb tail)."""
    program = lower(op, spec, impl, shard)
    return executor(program, spec, shard)(*operands)


# ---------------------------------------------------------------------------
# Builders: replicated lowerings (the PR 3 closures, now program-keyed)
# ---------------------------------------------------------------------------

def _build(txn: prg.Txn, specs: tuple, shard):
    if txn.layout is not None:
        return _build_sharded(txn, specs, shard)
    build = _BUILDERS[txn.op]
    return build(txn, specs)


def _gather_plan(txn: prg.Txn, specs: tuple):
    if txn.width > 1:
        return _gather_fused(txn, specs)
    spec, impl = specs[0], txn.impl
    s, o, vl = spec.stride, spec.offset, spec.vl
    if s < 0:
        from repro.core import accessfuse
        return lambda w: accessfuse.bank_gather_strided(w, s, o, vl)
    if impl == "ref":
        from repro.kernels import ref
        return lambda w: ref.gather_strided(w, s, o, vl)
    from repro.kernels import strided
    return lambda w: strided.gather_strided(w, s, o, vl,
                                            compiled=impl == "pallas")


def _gather_fused(txn: prg.Txn, specs: tuple):
    """Width-N strided super-transaction over a stacked (N, ..., n) window:
    one shared plan when homogeneous, the concatenated-mask kernel when
    heterogeneous, a stacked XLA loop under ref."""
    vl = specs[0].vl
    pairs = tuple((s.stride, s.offset) for s in specs)
    if txn.homogeneous:
        inner = _gather_plan(prg.Txn("gather.plan", txn.specs[:1], txn.impl),
                             specs[:1])
        return inner
    if txn.impl == "ref":
        from repro.kernels import ref

        def ref_many(windows):
            return jnp.stack([ref.gather_strided(windows[a], s, o, vl)
                              for a, (s, o) in enumerate(pairs)])

        return ref_many
    from repro.kernels import strided
    return lambda windows: strided.gather_strided_fused(
        windows, pairs, vl, compiled=txn.impl == "pallas")


def _scatter_plan(txn: prg.Txn, specs: tuple):
    spec, impl = specs[0], txn.impl
    s, o = spec.stride, spec.offset
    if s < 0:
        from repro.core import accessfuse
        return lambda w, v: accessfuse.bank_scatter_strided(w, v, s, o)
    if impl == "ref":
        from repro.kernels import ref
        return lambda w, v: ref.scatter_strided(w, v, s, o)
    from repro.kernels import strided
    return lambda w, v: strided.scatter_strided(w, v, s, o,
                                                compiled=impl == "pallas")


def _bank_gather(txn: prg.Txn, specs: tuple):
    spec = specs[0]
    from repro.core import accessfuse
    return lambda w, stride: accessfuse.bank_gather_strided(
        w, stride, spec.offset, spec.vl)


def _bank_scatter(txn: prg.Txn, specs: tuple):
    spec = specs[0]
    from repro.core import accessfuse
    return lambda w, v, stride: accessfuse.bank_scatter_strided(
        w, v, stride, spec.offset)


def _seg_deint(txn: prg.Txn, specs: tuple):
    fields, impl = specs[0].fields, txn.impl
    if impl == "ref":
        from repro.kernels import ref
        return lambda a: ref.deinterleave(a, fields)
    from repro.kernels import segment
    return lambda a: segment.deinterleave(a, fields,
                                          fused=impl == "pallas")


def _seg_int(txn: prg.Txn, specs: tuple):
    impl = txn.impl
    if impl == "ref":
        from repro.kernels import ref
        return lambda parts: ref.interleave(parts)
    from repro.kernels import segment
    return lambda parts: segment.interleave(parts, fused=impl == "pallas")


def _idx_gather(txn: prg.Txn, specs: tuple):
    spec = specs[0]
    if getattr(spec, "routing", None) is not None:
        # Static routing: the plan stage.  The layer take-masks are
        # computed ONCE here (concrete inputs -> concrete masks, even
        # under an outer jit trace) and the executor is memoized in
        # vx.PLANS under the spec key (routing included), so the payload
        # pays one static shift + one select per layer — on every impl,
        # since the masks are already compile-time constants.
        import numpy as np

        from repro.core import shiftnet
        shift = jnp.asarray(np.array(spec.routing[0], np.int32))
        valid = jnp.asarray(np.array(spec.routing[1], bool))
        masks, occ = shiftnet.layer_masks(shift, valid, toward_zero=True,
                                          lsb_first=True)

        def planned(buf):
            out = buf
            if masks.shape[0]:
                out = shiftnet.apply_layer_masks(out, masks, axis=-1,
                                                 toward_zero=True,
                                                 lsb_first=True)
            return jnp.where(occ, out, jnp.zeros_like(out))

        return planned
    if txn.impl == "ref":
        from repro.core import shiftnet

        def ref_idx(buf, shift, valid):
            res = shiftnet.gather_network(buf, shift, valid, axis=-1)
            return jnp.where(res.valid, res.payload,
                             jnp.zeros_like(res.payload))

        return ref_idx
    from repro.kernels import shift_gather as _sg
    return lambda buf, shift, valid: _sg.shift_gather(buf, shift, valid)


def _idx_scatter(txn: prg.Txn, specs: tuple):
    if txn.impl == "ref":
        from repro.core import shiftnet

        def ref_idx(values, shift, valid):
            res = shiftnet.scatter_network(values, shift, valid, axis=-1)
            return (jnp.where(res.valid, res.payload,
                              jnp.zeros_like(res.payload)),
                    jnp.broadcast_to(res.valid, values.shape))

        return ref_idx
    from repro.kernels import shift_scatter as _ss
    return lambda values, shift, valid: _ss.shift_scatter(values, shift,
                                                          valid)


def _compact_rows(txn: prg.Txn, specs: tuple):
    cap = specs[0].capacity

    if txn.impl == "ref":
        from repro.kernels import ref
        pack = ref.compact_rows
    else:
        from repro.kernels import moe_compact
        pack = moe_compact.compact_rows

    def fn(rows, mask):
        packed, valid = pack(rows, mask)
        if cap < packed.shape[0]:
            packed = jax.lax.slice_in_dim(packed, 0, cap, axis=0)
            valid = jax.lax.slice_in_dim(valid, 0, cap, axis=0)
        return packed, valid

    return fn


def _compact_ids(txn: prg.Txn, specs: tuple):
    cap = specs[0].capacity
    from repro.core import accessfuse
    return lambda mask: accessfuse.compact_indices(mask, cap)


def _paged_gather(txn: prg.Txn, specs: tuple):
    """Page-table gather: ``out[.., j, ..] = pool[.., t[j//ps], j%ps, ..]``.

    The table is a RUNTIME operand; only the geometry (page_size, pages,
    trail, dtype) is compiled state, so ONE cached executor serves every
    request and every decode step.  Page dispatch is one take at page
    granularity (each page is a contiguous beat — the access is already
    coalesced; the within-beat routing is the identity plan), entries
    ``< 0`` read as zeros.  Width-N fused transactions run on a stacked
    pool with ONE shared table — still a single gather (rank-agnostic:
    the page axis is found from the end).

    QUANTIZED specs (``scale_dtype`` set) take the per-page scale side
    tensor ``(*lead, P, *trail[:-1])`` as an extra operand and dequantize
    in the SAME program: the scale lookup is a one-hot contraction
    (iota + eq + dot — zero extra gather eqns, zero extra launches, and a
    ``-1`` table row one-hots to the zero vector), multiplied into the
    int page beats before the validity mask.  Masking AFTER the multiply
    matters for fp8: garbage on never-written pages can be NaN and
    ``0 * NaN`` would leak through a pre-mask.
    """
    spec = specs[0]
    ps, pages, trail = spec.page_size, spec.pages, spec.trail

    if spec.quantized:
        def qfn(pool, scales, table):
            pa = spec.pool_axis(pool.ndim)
            if pool.shape[pa + 1] != ps:
                raise ValueError(
                    f"pool axis {pa + 1} has {pool.shape[pa + 1]} lanes, "
                    f"spec.page_size is {ps}")
            if table.shape[-1] != pages:
                raise ValueError(
                    f"table has {table.shape[-1]} pages, "
                    f"spec.pages is {pages}")
            P = pool.shape[pa]
            want = pool.shape[:pa] + (P,) + pool.shape[pa + 2:-1]
            if tuple(scales.shape) != want:
                raise ValueError(
                    f"scales shape {scales.shape} != {want} (per page, "
                    f"per trail dim except the last) for pool "
                    f"{pool.shape}")
            valid = table >= 0
            ints = jnp.take(pool, jnp.maximum(table, 0), axis=pa)
            # one-hot scale lookup: (*batch, pages, P) @ (P, *lead, *th)
            oh = (table[..., None] == jnp.arange(P)).astype(scales.dtype)
            s = jnp.tensordot(oh, jnp.moveaxis(scales, pa, 0), axes=1)
            bt = table.ndim
            if pa:   # lead dims back to the front
                s = jnp.moveaxis(s, tuple(range(bt, bt + pa)),
                                 tuple(range(pa)))
            s = jnp.expand_dims(s, pa + bt)     # the in-page axis
            if trail:
                s = s[..., None]                # shared last trail dim
            out = ints.astype(s.dtype) * s
            vshape = ((1,) * pa + table.shape + (1,) + (1,) * trail)
            out = jnp.where(valid.reshape(vshape), out,
                            jnp.zeros_like(out))
            shape = (out.shape[:pa + bt - 1] + (pages * ps,)
                     + out.shape[pa + bt + 1:])
            return out.reshape(shape)

        return qfn

    def fn(pool, table):
        pa = spec.pool_axis(pool.ndim)
        if pool.shape[pa + 1] != ps:
            raise ValueError(
                f"pool axis {pa + 1} has {pool.shape[pa + 1]} lanes, "
                f"spec.page_size is {ps}")
        if table.shape[-1] != pages:
            raise ValueError(
                f"table has {table.shape[-1]} pages, spec.pages is {pages}")
        valid = table >= 0
        out = jnp.take(pool, jnp.maximum(table, 0), axis=pa)
        # out: (*lead, *batch, pages, ps, *trail); zero unallocated pages
        vshape = ((1,) * pa + table.shape + (1,) + (1,) * trail)
        out = jnp.where(valid.reshape(vshape), out, jnp.zeros_like(out))
        shape = (out.shape[:pa + table.ndim - 1] + (pages * ps,)
                 + out.shape[pa + table.ndim + 1:])
        return out.reshape(shape)

    return fn


def _paged_scatter(txn: prg.Txn, specs: tuple):
    """Decode append: one beat per table row, written through the page
    table at per-row position ``pos`` (``pos // ps`` picks the logical
    page, ``pos % ps`` the in-page offset).  Rows with ``pos < 0`` or an
    unallocated table entry are DROPPED (out-of-bounds scatter), so an
    inactive serving slot appends nothing.

    QUANTIZED specs append in three phases with a MONOTONE per-page
    scale (a page's scale only ever widens — shared CoW prefix pages are
    immutable, so a reader never races a rescale):

    1. scatter-max the beat's max-abs scale into the page's scale row,
    2. rescale the page's RESIDENT ints to the widened scale
       (``ratio = s_old / s_new <= 1``; a fresh page — ``s_old == 0`` —
       zeroes whatever garbage was resident).  Duplicate rows hitting
       the same physical page (chunked prefill writes up to ``ps`` beats
       into one page in a single scatter) write IDENTICAL content here:
       every read (s_old, s_new, the resident page) predates every
       write, so last-writer-wins is safe,
    3. quantize each beat at the final page scale and write it at its
       distinct ``(page, offset)`` — exactly the float arm's pattern.

    Returns ``(pool, scales)``."""
    spec = specs[0]
    ps, trail = spec.page_size, spec.trail

    if spec.quantized:
        from repro.core import quant

        def qfn(pool, scales, values, table, pos):
            pa = spec.pool_axis(pool.ndim)
            if pa != 0:
                raise NotImplementedError(
                    "quantized paged scatter wants the page axis leading "
                    "(no lead dims): per-lead beat scales have no "
                    "broadcast rule here")
            if trail < 1:
                raise NotImplementedError(
                    "quantized paged scatter needs >= 1 trailing dim "
                    "(the max-abs scale reduces over the last)")
            P = pool.shape[0]
            qm = quant.qmax(pool.dtype)
            pos = jnp.asarray(pos, jnp.int32)
            oob = (pos < 0) | (pos >= spec.pages * ps)
            page = jnp.where(oob, 0, pos // ps)
            phys = jnp.take_along_axis(table, page[..., None],
                                       axis=-1)[..., 0]
            drop = oob | (phys < 0)
            physd = jnp.where(drop, P, phys)     # out of bounds -> dropped
            off = jnp.where(drop, ps, pos % ps)
            safe = jnp.clip(phys, 0, P - 1)      # reads for dropped rows
            # 1. widen: beat scale per (*batch, *trail[:-1])
            s_beat = jnp.max(jnp.abs(values), axis=-1) / qm
            s_old = jnp.take(scales, safe, axis=0)
            scales = scales.at[physd].max(jnp.maximum(s_old, s_beat),
                                          mode="drop")
            s_fin = jnp.take(scales, safe, axis=0)
            # 2. rescale resident ints to the widened scale
            ratio = jnp.where(s_fin > 0,
                              s_old / jnp.where(s_fin > 0, s_fin, 1.0),
                              1.0)
            rb = jnp.expand_dims(ratio, pos.ndim)[..., None]
            pgs = jnp.take(pool, safe, axis=0)
            pool = pool.at[physd].set(
                quant.requantize(pgs.astype(rb.dtype) * rb, pool.dtype),
                mode="drop")
            # 3. quantize the beat at the final page scale (safe divide:
            # an all-zero beat on a fresh page keeps scale 0 and writes
            # 0 — never NaN, fp8 has NaN encodings)
            qb = quant.quantize(values, s_fin[..., None], pool.dtype)
            pool = pool.at[(physd, off)].set(qb, mode="drop")
            return pool, scales

        return qfn

    def fn(pool, values, table, pos):
        pa = spec.pool_axis(pool.ndim)
        P = pool.shape[pa]
        pos = jnp.asarray(pos, jnp.int32)
        oob = (pos < 0) | (pos >= spec.pages * ps)
        page = jnp.where(oob, 0, pos // ps)
        phys = jnp.take_along_axis(table, page[..., None], axis=-1)[..., 0]
        drop = oob | (phys < 0)
        phys = jnp.where(drop, P, phys)          # out of bounds -> dropped
        off = jnp.where(drop, ps, pos % ps)
        idx = (slice(None),) * pa + (phys, off)
        vals = values.astype(pool.dtype).reshape(
            (1,) * pa + values.shape)
        vals = jnp.broadcast_to(vals, pool.shape[:pa] + values.shape)
        return pool.at[idx].set(vals, mode="drop")

    return fn


def _compact_expand(txn: prg.Txn, specs: tuple):
    if txn.impl == "ref":
        from repro.kernels import ref
        return lambda packed, mask: ref.expand_rows(packed, mask)
    from repro.kernels import moe_compact
    return lambda packed, mask: moe_compact.expand_rows(packed, mask)


_BUILDERS = {
    "gather.plan": _gather_plan,
    "scatter.plan": _scatter_plan,
    "bank.gather": _bank_gather,
    "bank.scatter": _bank_scatter,
    "seg.deint": _seg_deint,
    "seg.int": _seg_int,
    "idx.gather": _idx_gather,
    "idx.scatter": _idx_scatter,
    "compact.rows": _compact_rows,
    "compact.ids": _compact_ids,
    "compact.expand": _compact_expand,
    "paged.gather": _paged_gather,
    "paged.scatter": _paged_scatter,
}


# ---------------------------------------------------------------------------
# Builders: sharded lowerings (shard-local plans under shard_map)
# ---------------------------------------------------------------------------

def _shard_index(shard: prg.Shard):
    """Flattened shard index, first mesh axis major (PartitionSpec order)."""
    idx = None
    for a in shard.axes:
        k = jax.lax.axis_index(a)
        idx = k if idx is None else idx * shard.mesh.shape[a] + k
    return idx


def _axis_spec(ndim: int, ax: int, shard: prg.Shard):
    from jax.sharding import PartitionSpec as P
    return P(*[shard.axes if i == ax else None for i in range(ndim)])


def _replicated_spec(ndim: int):
    from jax.sharding import PartitionSpec as P
    return P(*([None] * ndim))


def _shard_map(body, shard: prg.Shard, in_specs, out_specs):
    from repro.dist.sharding import shard_map
    # check_vma off: bodies branch on lax.axis_index (device-varying by
    # construction) and merge with an explicit psum
    return shard_map(body, mesh=shard.mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=False)


def _sub_strided(op: str, spec: Strided, impl: str, stride: int, cnt: int,
                 loff: int, nl: int):
    """The shard-local executor: the SAME pipeline, recursively, on the
    offset-rebased sub-spec (its program lands in vx.PLANS like any
    other).  ``stride`` is the Reverser-normalized (positive) stride."""
    import dataclasses
    sub = dataclasses.replace(spec, n=nl, stride=stride, offset=loff,
                              vl=cnt)
    return executor(lower(op, sub, impl), sub)


def _sharded_gather_plan(txn: prg.Txn, specs: tuple, shard: prg.Shard):
    from repro.core import shiftplan
    spec = specs[0]
    s, o, vl = spec.stride, spec.offset, spec.vl
    rev = s < 0
    if rev:                      # Reverser: plan on the flipped lane order
        o, s = o + (vl - 1) * s, -s
    R = shard.nshards
    nl = spec.n // R
    rows = shiftplan.shard_strided_rows(spec.n, s, o, vl, R)
    subs = [None if cnt == 0 else
            (lo, cnt, _sub_strided("gather.plan", spec, txn.impl,
                                   s, cnt, loff, nl))
            for lo, cnt, loff in rows]

    def mk(entry):
        if entry is None:
            return lambda x: jnp.zeros(x.shape[:-1] + (vl,), x.dtype)
        lo, cnt, sub = entry

        def br(x):
            dense = sub(x)
            pad = [(0, 0)] * (x.ndim - 1) + [(lo, vl - lo - cnt)]
            return jnp.pad(dense, pad)

        return br

    branches = [mk(e) for e in subs]

    def body(w):
        out = jax.lax.switch(_shard_index(shard), branches, w)
        # output lanes are disjoint across shards: psum == select
        return jax.lax.psum(out, shard.axes)

    def fn(w):
        ax = w.ndim - 1
        g = _shard_map(body, shard, (_axis_spec(w.ndim, ax, shard),),
                       _replicated_spec(w.ndim))
        out = g(w)
        return jnp.flip(out, -1) if rev else out

    return fn


def _sharded_scatter_plan(txn: prg.Txn, specs: tuple, shard: prg.Shard):
    from repro.core import shiftplan
    spec = specs[0]
    s, o = spec.stride, spec.offset
    vl = spec.vl
    rev = s < 0
    if rev:
        o, s = o + (vl - 1) * s, -s
    R = shard.nshards
    nl = spec.n // R
    rows = shiftplan.shard_strided_rows(spec.n, s, o, vl, R)
    subs = [None if cnt == 0 else
            (lo, cnt, _sub_strided("scatter.plan", spec, txn.impl,
                                   s, cnt, loff, nl))
            for lo, cnt, loff in rows]

    def mk(entry):
        if entry is None:
            return lambda x, v: x
        lo, cnt, sub = entry

        def br(x, v):
            vals = jax.lax.slice_in_dim(v, lo, lo + cnt, axis=-1)
            return sub(x, vals)

        return br

    branches = [mk(e) for e in subs]

    def body(w, v):
        return jax.lax.switch(_shard_index(shard), branches, w, v)

    def fn(w, v):
        ax = w.ndim - 1
        g = _shard_map(body, shard,
                       (_axis_spec(w.ndim, ax, shard),
                        _replicated_spec(v.ndim)),
                       _axis_spec(w.ndim, ax, shard))
        return g(w, jnp.flip(v, -1) if rev else v)

    return fn


def _sharded_seg_deint(txn: prg.Txn, specs: tuple, shard: prg.Shard):
    fields = specs[0].fields
    local = _seg_deint(txn, specs)

    def fn(aos):
        ax = aos.ndim + shard.axis
        if ax < 0 or ax == aos.ndim - 1:
            raise ValueError(f"shard axis {shard.axis} out of range for a "
                             f"rank-{aos.ndim} AoS operand")
        if aos.shape[ax] % shard.nshards:
            raise ValueError(
                f"operand dim {aos.shape[ax]} does not split into "
                f"{shard.nshards} shards")
        spec_in = _axis_spec(aos.ndim, ax, shard)
        g = _shard_map(lambda a: tuple(local(a)), shard, (spec_in,),
                       tuple(spec_in for _ in range(fields)))
        return list(g(aos))

    return fn


def _sharded_seg_int(txn: prg.Txn, specs: tuple, shard: prg.Shard):
    fields = specs[0].fields
    local = _seg_int(txn, specs)

    def fn(parts):
        parts = list(parts)
        ndim = parts[0].ndim
        ax = ndim + shard.axis
        if ax < 0 or ax == ndim - 1:
            raise ValueError(f"shard axis {shard.axis} out of range for a "
                             f"rank-{ndim} SoA operand")
        spec_in = _axis_spec(ndim, ax, shard)
        g = _shard_map(lambda *ps: local(list(ps)), shard,
                       tuple(spec_in for _ in range(fields)), spec_in)
        return g(*parts)

    return fn


def _sharded_paged_gather(txn: prg.Txn, specs: tuple, shard: prg.Shard):
    """Shard-local page gathers over a pool sharded on the page axis.

    Each shard owns a contiguous block of ``P // R`` physical pages; the
    (replicated) table is rebased into the local page-id space, entries
    owned elsewhere become ``-1`` (the replicated builder zeroes them),
    and ONE ``psum`` merges the disjoint per-shard contributions — every
    physical page has exactly one owner, so the psum is a select.  The
    sharded pool leaf is never sliced globally (the PR 4 invariant).

    Quantized pools shard the scale side tensor on the SAME page axis
    (scales are per physical page), so the inner quantized gather runs
    unchanged on the local page block with its local scales."""
    spec = specs[0]
    inner = _paged_gather(txn, specs)

    def fn(pool, *rest):
        scales, table = rest if spec.quantized else (None, rest[0])
        pa = spec.pool_axis(pool.ndim)
        P, R = pool.shape[pa], shard.nshards
        if P % R:
            raise ValueError(
                f"pool of {P} pages does not split into {R} equal shards")
        nl = P // R
        out_ndim = pool.ndim + table.ndim - 2

        def body(lp, tb):
            local = tb - _shard_index(shard) * nl
            owned = (tb >= 0) & (local >= 0) & (local < nl)
            out = inner(lp, jnp.where(owned, local, -1))
            return jax.lax.psum(out, shard.axes)

        def qbody(lp, ls, tb):
            local = tb - _shard_index(shard) * nl
            owned = (tb >= 0) & (local >= 0) & (local < nl)
            out = inner(lp, ls, jnp.where(owned, local, -1))
            return jax.lax.psum(out, shard.axes)

        pool_spec = _axis_spec(pool.ndim, pa, shard)
        if spec.quantized:
            g = _shard_map(qbody, shard,
                           (pool_spec, _axis_spec(scales.ndim, pa, shard),
                            _replicated_spec(table.ndim)),
                           _replicated_spec(out_ndim))
            return g(pool, scales, table)
        g = _shard_map(body, shard,
                       (pool_spec, _replicated_spec(table.ndim)),
                       _replicated_spec(out_ndim))
        return g(pool, table)

    return fn


_SHARDED_BUILDERS = {
    "gather.plan": _sharded_gather_plan,
    "scatter.plan": _sharded_scatter_plan,
    "seg.deint": _sharded_seg_deint,
    "seg.int": _sharded_seg_int,
    "paged.gather": _sharded_paged_gather,
}


def _build_sharded(txn: prg.Txn, specs: tuple, shard):
    if shard is None or shard.layout() != txn.layout:
        raise ValueError(
            f"program was lowered for layout {txn.layout} but executor "
            f"got {None if shard is None else shard.layout()}")
    if txn.op in ("gather.plan", "scatter.plan", "paged.gather") \
            and not txn.homogeneous:
        # a fused heterogeneous group reaches here through program.fuse
        # (per-access lower() only sees width 1): the sharded builder
        # compiles ONE rebased plan, which would silently apply spec 0's
        # pattern to every stacked row
        raise NotImplementedError(
            "heterogeneous fused strided transactions have no sharded "
            "lowering; gather replicated or split the group")
    return _SHARDED_BUILDERS[txn.op](txn, specs, shard)
